"""Minimal deterministic fallback for `hypothesis` when it isn't installed.

The tier-1 suite property-tests with hypothesis, but the pinned runtime
image may not ship it (it IS declared in pyproject's test extra and
installed in CI). To keep the suite collectable and meaningful everywhere,
`conftest.py` injects this stub into `sys.modules` only when the real
library is missing.

Scope: exactly what the tests here use — `given` (positional or keyword
strategies), `settings(max_examples=..., deadline=...)`, and the
`integers` / `floats` / `lists` / `tuples` / `sampled_from` strategies. Drawing is deterministic
(seeded per test) and always includes the strategy bounds, so boundary
cases are exercised on every run. It is NOT a general hypothesis
replacement: no shrinking, no database, no stateful testing.
"""
from __future__ import annotations

import functools
import inspect
import types

import numpy as np


class _Strategy:
    """A sampler: `draw(rng, i)` returns the i-th example."""

    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng, i: int):
        return self._draw(rng, i)


def integers(min_value: int, max_value: int) -> _Strategy:
    def draw(rng, i):
        if i == 0:
            return int(min_value)
        if i == 1:
            return int(max_value)
        return int(rng.integers(min_value, max_value + 1))
    return _Strategy(draw)


def floats(min_value: float, max_value: float, **_) -> _Strategy:
    def draw(rng, i):
        if i == 0:
            return float(min_value)
        if i == 1:
            return float(max_value)
        return float(rng.uniform(min_value, max_value))
    return _Strategy(draw)


def sampled_from(values) -> _Strategy:
    values = list(values)

    def draw(rng, i):
        if i < len(values):
            return values[i]
        return values[int(rng.integers(len(values)))]
    return _Strategy(draw)


def tuples(*strategies: _Strategy) -> _Strategy:
    def draw(rng, i):
        return tuple(s.draw(rng, i) for s in strategies)
    return _Strategy(draw)


def lists(elements: _Strategy, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    def draw(rng, i):
        size = int(rng.integers(min_size, max_size + 1))
        return [elements.draw(rng, 2 + int(rng.integers(0, 1 << 16)))
                for _ in range(size)]
    return _Strategy(draw)


def settings(max_examples: int = 10, **_):
    """Records `max_examples` on the function for `given` to honor."""
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples", 10))
            rng = np.random.default_rng(0)
            for i in range(n):
                drawn_args = [s.draw(rng, i) for s in arg_strategies]
                drawn_kwargs = {name: s.draw(rng, i)
                                for name, s in kw_strategies.items()}
                fn(*args, *drawn_args, **kwargs, **drawn_kwargs)
        # all params are strategy-drawn: hide them so pytest doesn't go
        # looking for fixtures with those names
        wrapper.__signature__ = inspect.Signature(parameters=[])
        return wrapper
    return deco


def install() -> None:
    """Register this stub as `hypothesis` in sys.modules."""
    import sys

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = integers
    strategies.floats = floats
    strategies.lists = lists
    strategies.sampled_from = sampled_from
    strategies.tuples = tuples
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
