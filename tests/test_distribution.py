"""Distribution correctness on multi-device host platforms.

These run in subprocesses because the forced host device count must be set
before JAX initializes (same constraint as launch/dryrun.py).
"""
import subprocess
import sys
import textwrap



def _run(script: str, devices: int = 8, timeout: int = 420):
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu", "PATH": "/usr/bin:/bin"}
    import os
    env["PATH"] = os.environ.get("PATH", env["PATH"])
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=".")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_cp_decode_matches_plain():
    """Context-parallel (shard_map) decode == single-device plain decode."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_config
        from repro.models import cpu_context, init_params, init_cache, prefill, decode_step
        from repro.models.parallel import ParallelContext

        cfg = get_config('gemma-2b').reduced()       # MQA: kv=1 -> CP path
        key = jax.random.key(0)
        params = init_params(key, cfg)
        B, S = 4, 16
        toks = jax.random.randint(key, (B, S + 4), 0, cfg.vocab_size)

        # reference: plain single-device decode
        ctx0 = cpu_context()
        cache = init_cache(cfg, B, 32)
        last0, cache0 = prefill(params, {'tokens': toks[:, :S]}, cache,
                                cfg=cfg, ctx=ctx0)
        l0, _ = decode_step(params, toks[:, S:S+1], cache0, jnp.int32(S),
                            cfg=cfg, ctx=ctx0)

        # CP: 2 data x 4 model; cache seq 32 % 4 == 0, kv_heads=1 % 4 != 0
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        ctx = ParallelContext(mesh=mesh, batch_axes=('data',),
                              model_axis='model')
        from repro.models.layers import kv_cache_cp
        assert kv_cache_cp(cfg.n_kv_heads, 32, ctx)
        cache = init_cache(cfg, B, 32)
        last1, cache1 = prefill(params, {'tokens': toks[:, :S]}, cache,
                                cfg=cfg, ctx=ctx)
        l1, _ = jax.jit(lambda p, t, c, pos: decode_step(
            p, t, c, pos, cfg=cfg, ctx=ctx))(params, toks[:, S:S+1],
                                             cache1, jnp.int32(S))
        np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                                   rtol=3e-2, atol=3e-2)
        print('CP decode OK')
    """)
    assert "CP decode OK" in out


def test_moe_ep_a2a_matches_dense():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models.moe import init_moe, moe_layer, moe_layer_ep_a2a
        from repro.models.parallel import ParallelContext, cpu_context

        cfg = get_config('deepseek-moe-16b').reduced()   # 4 experts, top-2
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        ctx = ParallelContext(mesh=mesh, batch_axes=('data',),
                              model_axis='model')
        key = jax.random.key(0)
        p = init_moe(key, cfg)
        x = jax.random.normal(jax.random.fold_in(key, 1),
                              (4, 32, cfg.d_model), jnp.float32)
        o1, _ = moe_layer(p, x, cfg=cfg, ctx=cpu_context())
        o2, _ = jax.jit(lambda p, x: moe_layer_ep_a2a(
            p, x, cfg=cfg, ctx=ctx, capacity_factor=8.0))(p, x)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=3e-3, atol=3e-3)
        # gradients flow through the a2a
        g = jax.grad(lambda p, x: jnp.sum(moe_layer_ep_a2a(
            p, x, cfg=cfg, ctx=ctx, capacity_factor=8.0)[0] ** 2))(p, x)
        assert all(bool(jnp.isfinite(leaf).all()) for leaf in jax.tree.leaves(g))
        print('ep_a2a OK')
    """)
    assert "ep_a2a OK" in out


def test_sharded_train_step_runs():
    """A real (tiny) sharded train step executes on an 8-device mesh."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import init_params, dummy_batch, params_shapes
        from repro.models.parallel import ParallelContext, param_shardings
        from repro.training import AdamWConfig, init_opt_state, make_train_step

        cfg = get_config('gemma-2b').reduced(n_layers=2, d_model=128,
                                             vocab_size=512)
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        ctx = ParallelContext(mesh=mesh, batch_axes=('data',),
                              model_axis='model')
        params = init_params(jax.random.key(0), cfg)
        pshard = param_shardings(params_shapes(cfg), ctx)
        params = jax.device_put(params, pshard)
        opt = init_opt_state(params)
        step = jax.jit(make_train_step(cfg, ctx, AdamWConfig(warmup_steps=1)))
        batch = dummy_batch(jax.random.key(1), cfg, 4, 32, 'train')
        batch = jax.device_put(batch, NamedSharding(mesh, P('data', None)))
        params, opt, metrics = step(params, opt, batch)
        assert bool(jnp.isfinite(metrics['loss']))
        print('sharded train OK', float(metrics['loss']))
    """)
    assert "sharded train OK" in out


def test_moe_capacity_matches_dense_cpu():
    from repro.configs import get_config
    from repro.models.moe import init_moe, moe_layer, moe_layer_capacity
    from repro.models.parallel import cpu_context
    import jax
    import jax.numpy as jnp
    import numpy as np

    cfg = get_config("mixtral-8x7b").reduced()
    ctx = cpu_context()
    key = jax.random.key(0)
    p = init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model),
                          jnp.float32)
    o1, _ = moe_layer(p, x, cfg=cfg, ctx=ctx)
    o2, _ = moe_layer_capacity(p, x, cfg=cfg, ctx=ctx, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-3,
                               atol=2e-3)
