import os
import sys

# Tests run on the real host device(s); only launch/dryrun forces 512.
# Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property tests use hypothesis (declared in pyproject's test extra and
# installed in CI). If the local runtime lacks it, fall back to the
# deterministic stub so the suite still collects and runs.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    _hypothesis_stub.install()
