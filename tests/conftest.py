import os
import sys

# Tests run on the real host device(s); only launch/dryrun forces 512.
# Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
