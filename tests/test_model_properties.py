"""Property-based tests on model invariants (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import cpu_context, dummy_batch, forward, init_params

CTX = cpu_context(remat=False)
CFG = get_config("gemma-2b").reduced(n_layers=2, d_model=64, vocab_size=128)
PARAMS = init_params(jax.random.key(0), CFG)


@given(pos=st.integers(4, 30), seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_causality(pos, seed):
    """Changing tokens at position >= pos never changes logits before pos."""
    key = jax.random.key(seed)
    toks = jax.random.randint(key, (1, 32), 0, CFG.vocab_size)
    l1, _, _ = forward(PARAMS, {"tokens": toks}, cfg=CFG, ctx=CTX,
                       mode="train")
    toks2 = toks.at[0, pos:].set((toks[0, pos:] + 7) % CFG.vocab_size)
    l2, _, _ = forward(PARAMS, {"tokens": toks2}, cfg=CFG, ctx=CTX,
                       mode="train")
    np.testing.assert_allclose(np.asarray(l1[:, :pos]),
                               np.asarray(l2[:, :pos]), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "recurrentgemma-2b"])
def test_causality_recurrent(arch):
    """SSM / RG-LRU recurrences are causal too."""
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, 32), 0, cfg.vocab_size)
    l1, _, _ = forward(params, {"tokens": toks}, cfg=cfg, ctx=CTX,
                       mode="train")
    toks2 = toks.at[0, 16:].set((toks[0, 16:] + 3) % cfg.vocab_size)
    l2, _, _ = forward(params, {"tokens": toks2}, cfg=cfg, ctx=CTX,
                       mode="train")
    np.testing.assert_allclose(np.asarray(l1[:, :16]),
                               np.asarray(l2[:, :16]), rtol=1e-3, atol=1e-3)


@given(perm_seed=st.integers(0, 50))
@settings(max_examples=8, deadline=None)
def test_batch_permutation_equivariance(perm_seed):
    """Permuting the batch permutes the logits identically."""
    toks = jax.random.randint(jax.random.key(3), (4, 16), 0, CFG.vocab_size)
    perm = jax.random.permutation(jax.random.key(perm_seed), 4)
    l1, _, _ = forward(PARAMS, {"tokens": toks}, cfg=CFG, ctx=CTX,
                       mode="train")
    l2, _, _ = forward(PARAMS, {"tokens": toks[perm]}, cfg=CFG, ctx=CTX,
                       mode="train")
    np.testing.assert_allclose(np.asarray(l1[perm]), np.asarray(l2),
                               rtol=1e-4, atol=1e-4)


@given(s=st.sampled_from([17, 24, 31, 48]))
@settings(max_examples=4, deadline=None)
def test_ssd_padding_invariance(s):
    """SSD output for a length-s input is unaffected by chunk padding."""
    from repro.models.ssm import ssd_chunked
    key = jax.random.key(9)
    ks = jax.random.split(key, 5)
    b, h, p, n = 1, 2, 4, 8
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.2)
    Bm = jax.random.normal(ks[3], (b, s, n)) * 0.3
    Cm = jax.random.normal(ks[4], (b, s, n)) * 0.3
    y16, f16 = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    y_s, f_s = ssd_chunked(x, dt, A, Bm, Cm, chunk=s)  # single chunk
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y_s),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(f16), np.asarray(f_s),
                               rtol=1e-4, atol=1e-4)


def test_loss_invariant_to_masked_labels():
    """Positions with label = -1 don't contribute to the loss."""
    from repro.models import loss_fn
    batch = dummy_batch(jax.random.key(5), CFG, 2, 16, "train")
    l1, _ = loss_fn(PARAMS, batch, cfg=CFG, ctx=CTX)
    # mask half the labels; loss must change only through normalization,
    # i.e. equal to the mean over the remaining positions
    labels2 = batch["labels"].at[:, ::2].set(-1)
    l2, m2 = loss_fn(PARAMS, {**batch, "labels": labels2}, cfg=CFG, ctx=CTX)
    assert bool(jnp.isfinite(l2))
    # and fully-masked rows don't produce NaNs
    labels3 = jnp.full_like(batch["labels"], -1)
    l3, _ = loss_fn(PARAMS, {**batch, "labels": labels3}, cfg=CFG, ctx=CTX)
    assert bool(jnp.isfinite(l3))
