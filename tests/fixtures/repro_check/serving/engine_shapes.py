"""Seeded R8 violation: a per-request prompt length flowing straight
into a jitted callee's operand shape — every distinct prompt length
silently recompiles. This is the pre-fix shape of the engine's prefill
path before pow-2 bucketing bounded the compile set.
"""
import jax
import jax.numpy as jnp


class MiniEngine:
    def __init__(self, params):
        self.params = params
        self.queue = []
        self._prefill = jax.jit(lambda p, t: t)

    def step(self):
        req = self.queue.pop(0)
        # unpadded per-request length → one compile per prompt length
        tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
        return self._prefill(self.params, tokens)       # R8 finding
