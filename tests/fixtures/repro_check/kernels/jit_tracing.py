"""Seeded R7 violations: Python control flow and host sync on tracers.

Pre-fix shapes of the tracing bugs R7 exists to catch. Each hazard line
is a distinct finding; tests/test_repro_check.py pins them.
"""
import functools

import jax
import jax.numpy as jnp


@jax.jit
def traced_branch(x, threshold):
    # Python `if` on a traced comparison: ConcretizationTypeError at
    # trace time (or a silently baked branch under custom tracers)
    if x > threshold:                                   # R7 finding
        return x * 2.0
    return x


@functools.partial(jax.jit, static_argnames=("block",))
def host_sync(x, block):
    total = jnp.sum(x)
    n = int(total)                                      # R7 finding
    print("total", total)                               # R7 finding
    return total.item() + n + block                     # R7 finding
