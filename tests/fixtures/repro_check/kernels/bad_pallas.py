"""Seeded R9 violations: a pallas_call whose wiring disagrees with
itself — wrong index-map arities, an out_shape of the wrong rank, an
operand count that doesn't match in_specs, and no interpret guard.
Every one of these traces fine in places Pallas doesn't validate until
TPU lowering; R9 catches them at lint time.
"""
import jax
from jax.experimental import pallas as pl


def _bad_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def double_blocks(x):
    m, n = x.shape
    grid = (m // 8,)
    return pl.pallas_call(                              # R9: no interpret=
        _bad_kernel,
        grid=grid,
        in_specs=[
            # R9: 2-arg index map for a rank-1 grid
            pl.BlockSpec((8, 128), lambda i, j: (i, 0)),
        ],
        # R9: 3-coordinate index map for a rank-2 block shape, and the
        # block rank disagrees with the rank-3 out_shape below
        out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n, 1), x.dtype),
    )(x, x)                                             # R9: 2 operands, 1 spec
