# Seeded-violation fixture for repro-check (NOT part of the package).
#
# This reproduces the PR 6 orphaned-pages bug shape, pre-fix: a request
# requeued onto a different server abandons its preserved KV pages by
# resetting the claim record without freeing them on the old server —
# `kv_used[old]` stays charged forever and the pool silently shrinks.
# The shipped fix calls `_prefix_unpin` + `_kv_free` before the reset
# (src/repro/cluster/simulator.py, dispatch). R1 must flag this file,
# and `python -m tools.repro_check tests/fixtures/repro_check` must
# exit non-zero.


class _EventSimRuntime:
    def dispatch(self, t, req, decision):
        j = decision.server
        if req.kv_server >= 0 and req.kv_server != j:
            # BUG (pre-PR 6 fix): pages preserved on another server are
            # abandoned without release — no _prefix_unpin, no _kv_free
            self.n_kv_orphaned += 1
            req.kv_server, req.kv_blocks = -1, 0
        self._submit(t, req, decision)

    def on_preempt_drop(self, req, b, t):
        # BUG shape 2 (the R1b half): pages freed and the claim record
        # reset, but the shared-prefix pin is never released — the pin
        # ledger leaks and the prefix entry can never be reclaimed
        self._kv_free(b.j, req.kv_blocks, t)
        req.kv_server, req.kv_blocks = -1, 0

    def book_first_hop_only(self, j, end):
        # BUG shape 3 (R1d, vectorized era): books only the first link
        # of the path — neither a `for ... in path` loop, a whole-path
        # index, nor a guarded single-link fast path
        lk = self.topo.paths[j][0]
        self.link_free[lk] = end

