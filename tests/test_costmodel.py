"""Jaxpr cost model: exact FLOPs on known programs, scan trip-count fix."""
import jax
import jax.numpy as jnp

from repro.launch.costmodel import step_cost


def test_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = step_cost(lambda x, y: x @ y, a, b)
    assert c["flops"] == 2 * 64 * 128 * 32
    assert c["bytes"] == (64 * 128 + 128 * 32 + 64 * 32) * 4


def test_scan_multiplies_by_trip_count():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    c = step_cost(f, x, w)
    assert c["flops"] == 10 * 2 * 128 ** 3


def test_grad_counts_forward_and_backward():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def loss(x, w):
        return jnp.sum((x @ w) ** 2)

    fwd = step_cost(loss, x, w)["flops"]
    both = step_cost(jax.grad(loss, argnums=1), x, w)["flops"]
    # grad wrt w = fwd matmul + one transposed matmul ≈ 2× fwd
    assert both >= 1.9 * fwd


def test_remat_increases_flops():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def net(x, w):
        for _ in range(4):
            x = jnp.tanh(x @ w)
        return jnp.sum(x)

    plain = step_cost(jax.grad(net, argnums=0), x, w)["flops"]
    rem = step_cost(jax.grad(jax.checkpoint(net), argnums=0), x, w)["flops"]
    assert rem > plain
