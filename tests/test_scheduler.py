"""PerLLM scheduler invariants — unit + hypothesis property tests.

Invariants from the paper's formulation (Eq. 2):
  C4 — every service is assigned exactly one server (structural);
  feasibility filter — an arm reported feasible has f(y) ≥ 0 under the
      scheduler's own prediction;
  capacity accounting — within-slot commits monotonically consume uplink
      and lane capacity;
  CS-UCB — regret grows sublinearly on stationary bandits and respects the
      Eq. 7 bound; constraint-violating arms are suppressed by P(t).
"""
import copy

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.cluster import (
    BandwidthModel, ClusterView, Simulator, generate_workload, paper_testbed,
)
from repro.cluster.workload import ServiceRequest, classify
from repro.core import (
    CSUCB, CSUCBParams, PerLLMScheduler, drive_slot, make_baselines,
)
from repro.core.constraints import evaluate_constraints


def _view(specs, t=0.0):
    return ClusterView(t=t, specs=specs, bw_factor=[1.0] * len(specs),
                       uplink_free_at=[0.0] * len(specs),
                       lane_free=[[0.0] * s.max_concurrency for s in specs])


def _req(sid=0, arrival=0.0, prompt=256, out=16, deadline=4.0,
         payload=2e6):
    r = ServiceRequest(sid=sid, arrival=arrival, prompt_tokens=prompt,
                       output_tokens=out, deadline=deadline,
                       payload_bytes=payload)
    r.class_id = classify(r)
    return r


# ---------------------------------------------------------------------------
# Constraint mechanism
# ---------------------------------------------------------------------------


@given(prompt=st.integers(32, 2048), out=st.integers(4, 96),
       deadline=st.floats(2.0, 6.0), payload=st.floats(0.5e6, 6.5e6))
@settings(max_examples=40, deadline=None)
def test_constraint_slacks_bounded(prompt, out, deadline, payload):
    specs = paper_testbed()
    view = _view(specs)
    req = _req(prompt=prompt, out=out, deadline=deadline, payload=payload)
    for j in range(len(specs)):
        s = evaluate_constraints(req, j, view)
        # normalized slacks can never exceed 1
        assert s.time <= 1.0 and s.compute <= 1.0 and s.bandwidth <= 1.0
        assert s.f == min(s.time, s.compute, s.bandwidth)
        assert s.satisfied == (s.f >= 0)


def test_commit_consumes_capacity():
    specs = paper_testbed()
    view = _view(specs)
    req = _req()
    j = len(specs) - 1
    before_up = view.uplink_free_at[j]
    before_lane = sorted(view.lane_free[j])
    t0 = view.predict_total(req, j)
    view.commit(req, j)
    assert view.uplink_free_at[j] > before_up
    assert sorted(view.lane_free[j]) != before_lane
    # the same request predicted again now takes at least as long
    assert view.predict_total(req, j) >= t0 - 1e-9


def test_constraint_violation_when_overloaded():
    specs = paper_testbed()
    view = _view(specs)
    req = _req(deadline=2.0)
    j = len(specs) - 1
    for _ in range(200):           # flood the cloud
        view.commit(req, j)
    s = evaluate_constraints(req, j, view)
    assert not s.satisfied


# ---------------------------------------------------------------------------
# C4 + scheduling behaviour
# ---------------------------------------------------------------------------


def test_every_service_assigned_exactly_once():
    specs = paper_testbed()
    services = generate_workload(400, seed=3)
    sim = Simulator(specs, BandwidthModel(), seed=5)
    sched = PerLLMScheduler(len(specs))
    res = sim.run([copy.copy(s) for s in services], sched)
    assert res.n_services == 400
    assert sum(res.per_server_served) == 400          # C4


def test_perllm_beats_baselines():
    specs = paper_testbed()
    services = generate_workload(1500, seed=0)
    results = {}
    for sched in [PerLLMScheduler(len(specs))] + make_baselines(len(specs)):
        sim = Simulator(specs, BandwidthModel(), seed=42)
        results[sched.name] = sim.run(
            [copy.copy(s) for s in services], sched)
    per = results["PerLLM"]
    assert per.success_rate > 0.9
    for name in ("FineInfer", "AGOD", "RewardlessGuidance"):
        assert per.success_rate > results[name].success_rate, name
    assert per.total_energy < results["FineInfer"].total_energy


# ---------------------------------------------------------------------------
# CS-UCB bandit
# ---------------------------------------------------------------------------


def test_csucb_forced_exploration_then_convergence():
    rng = np.random.default_rng(0)
    bandit = CSUCB(1, 4, CSUCBParams(delta=0.4))
    true_mean = np.array([0.1, 0.5, 0.3, 0.9])
    pulls = []
    for _ in range(800):
        a = bandit.select(0, np.ones(4, bool))
        r = true_mean[a] + rng.normal(0, 0.05)
        bandit.update(0, a, r, violation_severity=0.0)
        pulls.append(a)
    # every arm explored at least once, best arm dominates eventually
    assert set(pulls) == {0, 1, 2, 3}
    assert np.mean(np.array(pulls[-200:]) == 3) > 0.9


def test_csucb_penalty_suppresses_violating_arm():
    bandit = CSUCB(1, 2, CSUCBParams(theta=2.0, delta=0.1))
    for _ in range(100):
        a = bandit.select(0, np.ones(2, bool))
        if a == 0:   # arm 0: good reward but violates constraints
            bandit.update(0, 0, 0.8, violation_severity=1.0)
        else:
            bandit.update(0, 1, 0.5, violation_severity=0.0)
    later = [bandit.select(0, np.ones(2, bool)) for _ in range(20)]
    assert np.mean(later) > 0.8    # mostly the compliant arm


def test_csucb_regret_sublinear_and_bounded():
    rng = np.random.default_rng(1)
    bandit = CSUCB(2, 3, CSUCBParams(alpha=1.0, beta=1.0, delta=0.3))
    means = np.array([[0.2, 0.6, 0.4], [0.7, 0.1, 0.3]])
    for t in range(2000):
        cls = t % 2
        a = bandit.select(cls, np.ones(3, bool))
        bandit.update(cls, a, means[cls, a] + rng.normal(0, 0.05), 0.0)
    trace = np.array(bandit.regret_trace)
    # sublinear: second-half regret growth < first-half growth
    n = len(trace)
    first = trace[n // 2] - trace[0]
    second = trace[-1] - trace[n // 2]
    assert second < first
    assert bandit.regret_bound() > 0


@given(st.lists(st.floats(0.0, 1.0), min_size=4, max_size=4))
@settings(max_examples=20, deadline=None)
def test_csucb_select_respects_mask(rewards):
    bandit = CSUCB(1, 4)
    for a, r in enumerate(rewards):
        bandit.update(0, a, r, 0.0)
    mask = np.array([False, True, False, True])
    for _ in range(10):
        assert mask[bandit.select(0, mask)]


def test_infeasible_fallback_prefers_fastest():
    """Paper: with no feasible server, go to the most resource-rich one."""
    specs = paper_testbed()
    sched = PerLLMScheduler(len(specs))
    view = _view(specs)
    req = _req(deadline=0.01)     # impossible deadline: nothing feasible
    (decision,) = drive_slot(sched, [req], view)
    times = [view.predict_total(req, j) for j in range(len(specs))]
    # commit changed residuals, but the cloud (fastest) should win
    assert decision.server == int(np.argmin(times))
