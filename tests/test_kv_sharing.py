"""KV page sharing & migration: ref-counted prefix index, copy-on-write
forking, and cross-server KV transfer over the link topology.

Covers the PR's invariants: any interleaving of prefix-shared allocation,
COW forking, release, export/import migration, and index reclaim conserves
the block pool — no block leaks, no block is double-freed, and every
block's refcount equals its actual holder count; nominal non-shared runs
stay bit-exact with sharing enabled; an engine prefix hit skips the shared
prefill yet generates bit-identically to a cold engine; in the simulator
shared-prefix workloads bank measurable prefill savings, a cross-server
requeue with `Decision.migrate_kv` resumes with zero re-prefill while its
transfer occupies the per-link bandwidth ledgers, and a refused migration
is counted (`n_kv_orphaned`), not silently dropped; slotted mode refuses
both knobs loudly; and the `shared-prefix` scenario shapes Zipf-reused
system-prompt pools onto the baseline workload.
"""
import copy
import dataclasses
from collections import Counter

import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster import Simulator, generate_workload, paper_testbed
from repro.cluster.simulator import _EventSimRuntime
from repro.cluster.workload import classify
from repro.core import Arrival, Decision, SchedulingPolicy, make_policy
from repro.core.api import ClusterView


# ---------------------------------------------------------------------------
# PagedKVCache: sharing/COW/migration conservation (pure accounting)
# ---------------------------------------------------------------------------


_CFG = None


def _tiny_cache(n_blocks=16, block_tokens=4):
    global _CFG
    pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.serving.kvcache import PagedKVCache

    if _CFG is None:
        _CFG = get_config("gemma-2b").reduced(n_layers=2, d_model=128,
                                              vocab_size=512)
    return PagedKVCache(_CFG, n_blocks=n_blocks, block_tokens=block_tokens,
                        max_seq=32)


def _assert_conserved(cache, tables):
    """Every block's refcount equals its holder count (live tables plus
    index nodes), and unreferenced blocks are exactly the free pool."""
    held = Counter(b for t in tables for b in t.blocks)
    held += Counter(n.block for n in cache.prefix._nodes())
    for blk in range(cache.n_blocks):
        assert cache.allocator.refcount(blk) == held.get(blk, 0), blk
    assert cache.allocator.free_blocks == cache.n_blocks - len(held)


@given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 10 ** 6)),
                max_size=30))
@settings(max_examples=20, deadline=None)
def test_sharing_conservation_under_interleaving(ops):
    """Random interleavings of prefix-shared allocate / register / fork /
    release / export+import / reclaim never leak or double-free blocks."""
    cache = _tiny_cache()
    bt = cache.block_tokens
    assert cache.supports_prefix
    # three system-prompt pools of two full blocks each; suffixes vary
    pools = [list(range(64 + p * 2 * bt, 64 + (p + 1) * 2 * bt))
             for p in range(3)]
    tables = []
    for code, r in ops:
        if code == 0:                       # admit sharing a pool's prefix
            prompt = pools[r % 3] + [1 + (r // 3) % 400, 1 + (r // 7) % 400]
            t = cache.allocate(len(prompt) + 2, prompt=prompt)
            if t is not None:
                tables.append(t)
                cache.register_prefix(prompt, t)
        elif code == 1 and tables:          # copy-on-write fork
            t2 = cache.fork(tables[r % len(tables)])
            if t2 is not None:
                tables.append(t2)
        elif code == 2 and tables:          # release
            cache.free(tables.pop(r % len(tables)))
        elif code == 3 and tables:          # migrate: export, re-import, swap
            idx = r % len(tables)
            old = tables[idx]
            moved = cache.import_pages(cache.export(old), len(old.blocks))
            if moved is not None:
                tables[idx] = moved
                cache.free(old)
        else:                               # memory pressure on the index
            cache.prefix.reclaim(cache.n_blocks)
        _assert_conserved(cache, tables)
    for t in tables:
        cache.free(t)
    cache.prefix.clear()
    assert cache.allocator.free_blocks == cache.n_blocks


# ---------------------------------------------------------------------------
# Engine: prefix hits are bit-exact; non-shared runs unchanged
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def engine_setup():
    jax = pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.models import init_params

    cfg = get_config("gemma-2b").reduced(n_layers=2, d_model=128,
                                         vocab_size=512)
    return cfg, init_params(jax.random.key(0), cfg)


def _engine(engine_setup, **kw):
    from repro.serving import ServingEngine
    cfg, params = engine_setup
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_seq", 128)
    return ServingEngine(cfg, params, **kw)


def test_golden_disjoint_prompts_unchanged_by_sharing(engine_setup):
    """Nominal non-shared runs stay bit-exact: with no common full block
    between prompts, the sharing engine takes zero hits and generates
    exactly what a sharing-disabled engine does."""
    on = _engine(engine_setup, paged=True, kv_block_tokens=16)
    off = _engine(engine_setup, paged=True, kv_block_tokens=16,
                  prefix_sharing=False)
    prompts = [list(range(5 + i, 29 + i)) for i in range(4)]  # shifted heads
    for eng in (on, off):
        for p in prompts:
            eng.submit(list(p), max_new_tokens=6)
        eng.run_until_idle()
    assert [r.generated for r in on.completed] \
        == [r.generated for r in off.completed]
    assert on.n_prefix_hits == 0 and off.n_prefix_hits == 0
    # reclaimable-inclusive drain: the index may still hold pages, but
    # they are all surrenderable capacity
    assert on.kv.free_blocks == on.kv.n_blocks


def test_prefix_hit_skips_prefill_bit_exact(engine_setup):
    """A second request opening with a resident 2-block system prompt
    reuses those pages (skipping their prefill) and still generates
    bit-identically to a sharing-disabled engine."""
    shared = list(range(100, 132))          # 32 tokens = 2 full blocks
    p1 = shared + list(range(7, 15))
    p2 = shared + list(range(200, 208))
    cold = _engine(engine_setup, paged=True, kv_block_tokens=16,
                   prefix_sharing=False)
    warm = _engine(engine_setup, paged=True, kv_block_tokens=16)
    for eng in (cold, warm):
        eng.submit(list(p1), max_new_tokens=6)
        eng.run_until_idle()
        eng.submit(list(p2), max_new_tokens=6)
        eng.run_until_idle()
    assert [r.generated for r in warm.completed] \
        == [r.generated for r in cold.completed]
    assert warm.n_prefix_hits == 1
    assert warm.prefix_tokens_reused == 32
    assert cold.n_prefix_hits == 0
    assert warm.kv.free_blocks == warm.kv.n_blocks


# ---------------------------------------------------------------------------
# Simulator: shared-prefix ledger, migration, orphan counting
# ---------------------------------------------------------------------------


def _kv_specs(n=2, kv_blocks=64, block_tokens=64, lanes=1):
    base = paper_testbed(n_edge=max(n, 1))[:n]
    return [dataclasses.replace(s, name=f"e{i}", max_concurrency=lanes,
                                kv_blocks=kv_blocks,
                                kv_block_tokens=block_tokens)
            for i, s in enumerate(base)]


class _ScriptedPreempt(SchedulingPolicy):
    """Victim + preemptor pinned to server 0; the victim's requeue routes
    to `requeue_to`, optionally asking for a KV migration."""

    name = "scripted-preempt"

    def __init__(self, preemptor_sid, requeue_to, migrate=False):
        self.preemptor_sid = preemptor_sid
        self.requeue_to = requeue_to
        self.migrate = migrate

    def assign(self, req, view):
        if req.sid == self.preemptor_sid:
            tasks = view.running[0]
            return Decision(server=0,
                            preempt_victim=tasks[0].sid if tasks else None)
        if req.preemptions:
            return Decision(server=self.requeue_to, migrate_kv=self.migrate)
        return Decision(server=0)


class _RecordingRuntime(_EventSimRuntime):
    def __init__(self, sim, policy):
        super().__init__(sim, policy)
        self.bookings = []

    def dispatch(self, t, req, decision, **kw):
        super().dispatch(t, req, decision, **kw)
        if req.sid in self._inflight:
            self.bookings.append(self._inflight[req.sid])


def _run_migration(migrate):
    sim = Simulator(_kv_specs(), slot=None, seed=0)
    a, b = [copy.copy(s) for s in generate_workload(2, seed=0)]
    a.arrival, b.arrival = 0.0, 2.0
    a.prompt_tokens, a.output_tokens = 1024, 96
    b.prompt_tokens, b.output_tokens = 64, 8
    a.payload_bytes = b.payload_bytes = 1e6
    for r in (a, b):
        r.class_id = classify(r)
        r.preemptions = 0
        r.kv_server, r.kv_blocks = -1, 0
    rt = _RecordingRuntime(sim, _ScriptedPreempt(b.sid, requeue_to=1,
                                                 migrate=migrate))
    rt.loop.push(Arrival(0.0, requests=(a,)))
    rt.loop.push(Arrival(2.0, requests=(b,)))
    rt.drain()
    return rt, a, b


def test_migration_resumes_with_zero_reprefill_and_occupies_links():
    """Acceptance property: a cross-server requeue with `migrate_kv` ships
    the victim's pages over the topology — the continuation books a
    decode-only window (full prompt banked as savings) and the transfer
    holds every link on the union path busy for its serialization time."""
    rt, a, _ = _run_migration(migrate=True)
    assert rt.n_preempted == 1
    assert rt.n_kv_migrations == 1
    assert rt.kv_migrated_bytes > 0
    assert rt.n_kv_orphaned == 0
    assert rt.kv_prefill_tokens_saved == 1024       # zero re-prefill
    requeues = [bk for bk in rt.bookings
                if bk.request.sid == a.sid and not bk.cancelled]
    (bk,) = requeues
    assert bk.j == 1 and bk.kv_resumed
    spec = rt.specs[1]
    # decode-only: far below a full re-prefill of the 1024-token prompt
    assert bk.t_inf < spec.service_time(1024, a.output_tokens) / 0.7 \
        - spec.prefill_time(1024) / 2
    # the pages' serialization time is charged against every link on the
    # union of both servers' paths: none frees before preemption + transfer
    path = rt.topo.migration_path(0, 1)
    bw = rt.topo.migration_bandwidth(0, 1, rt._link_factors, rt.link_scale)
    dur = rt.kv_migrated_bytes * 8.0 / bw
    assert dur > 0
    assert min(rt.link_free[name] for name in path) >= 2.0 + dur * (1 - 1e-9)
    assert rt.kv_used == [0, 0]                     # ledger drains


def test_refused_migration_is_counted_not_silent():
    """Without `migrate_kv` the cross-server requeue abandons its pages:
    the drop is surfaced as `n_kv_orphaned` and the continuation pays the
    full re-prefill (no savings banked)."""
    rt, _, _ = _run_migration(migrate=False)
    assert rt.n_preempted == 1
    assert rt.n_kv_migrations == 0
    assert rt.n_kv_orphaned == 1
    assert rt.kv_prefill_tokens_saved == 0
    assert rt.kv_used == [0, 0]


def test_sim_shared_prefix_saves_prefill():
    """On the shared-prefix scenario the event simulator takes prefix
    hits and banks their prefill tokens; stripping the pool identities
    from the identical workload yields none."""
    specs = _kv_specs(n=2, kv_blocks=96, lanes=2)
    policy = make_policy("perllm", len(specs))
    shared = generate_workload(60, seed=3, scenario="shared-prefix")
    res = Simulator(specs, slot=None, seed=0).run(shared, policy)
    stripped = generate_workload(60, seed=3, scenario="shared-prefix")
    for r in stripped:
        r.prefix_id, r.prefix_tokens = -1, 0
    res0 = Simulator(specs, slot=None, seed=0).run(stripped, policy)
    assert res.n_prefix_hits > 0
    assert res.kv_prefill_tokens_saved > 0
    assert res0.n_prefix_hits == 0


def test_view_prefix_hit_tokens_clips_to_own_full_blocks():
    specs = _kv_specs()                     # kv_block_tokens = 64
    view = ClusterView(t=0.0, specs=specs, bw_factor=[1.0, 1.0],
                       uplink_free_at=[0.0, 0.0], lane_free=[[0.0], [0.0]],
                       running=[[], []],
                       kv_free_blocks=[64, 64], kv_total_blocks=[64, 64],
                       kv_prefix_tokens=[{7: 256}, {}])
    req = copy.copy(generate_workload(1, seed=0)[0])
    req.prompt_tokens = 280
    req.prefix_id, req.prefix_tokens = 7, 300
    # resident 256 < own full-block span min(300, 279)//64*64 = 256
    assert view.prefix_hit_tokens(req, 0) == 256
    assert view.prefix_hit_tokens(req, 1) == 0      # nothing resident
    req.prefix_tokens = 100                         # one full block only
    assert view.prefix_hit_tokens(req, 0) == 64
    req.prefix_id = -1
    assert view.prefix_hit_tokens(req, 0) == 0


# ---------------------------------------------------------------------------
# Slotted mode: loud refusal instead of silent mis-accounting
# ---------------------------------------------------------------------------


def test_slotted_construction_rejected_for_kv_workloads():
    """Slotted mode is retired; KV-sharing workloads always run on the
    event cores, so the historical slotted rejections are now a single
    construction-time error."""
    with pytest.raises(ValueError, match="slotted mode was removed"):
        Simulator(_kv_specs(), slot=0.5, seed=0)


# ---------------------------------------------------------------------------
# Scenario: Zipf-reused system-prompt pools
# ---------------------------------------------------------------------------


def test_shared_prefix_scenario_shapes_pools():
    base = generate_workload(200, seed=1)
    shaped = generate_workload(200, seed=1, scenario="shared-prefix")
    assert all(r.prefix_id >= 0 and r.prefix_tokens > 0 for r in shaped)
    # the system prompt is *prepended*: prompts grow by exactly the prefix
    by_sid = {r.sid: r for r in base}
    assert all(r.prompt_tokens
               == by_sid[r.sid].prompt_tokens + r.prefix_tokens
               for r in shaped)
    # Zipf reuse: a few pools dominate, yet more than one pool exists
    counts = Counter(r.prefix_id for r in shaped)
    assert len(counts) > 1
    assert counts.most_common(1)[0][1] > len(shaped) / len(counts)
    # arrivals stay the baseline Poisson process (request-for-request
    # comparable against the unshared workload)
    assert [r.arrival for r in shaped] == [r.arrival for r in base]
