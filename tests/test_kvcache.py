"""Paged KV-cache subsystem: allocator, engine paging, KV-preserving
preemption, and KV memory as a scheduling resource.

Covers the PR's invariants: the block allocator conserves its pool; a
paged engine generates bit-identically to the dense engine and its
admission stalls (FIFO) under KV pressure instead of oversubscribing;
evict → resubmit resumes decoding from the snapshot with zero re-prefill
(continuity: same tokens as an uninterrupted run); in the simulator a
same-server requeue after preemption charges no re-prefill while a
cross-server requeue charges the full prompt, and the block ledger always
drains; PerLLM's admission control sheds requests off `kv_free_blocks`
exhaustion; and the `kv-pressure` scenario reshapes the workload toward
memory-bound long-context services.
"""
import copy
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster import Simulator, generate_workload, paper_testbed
from repro.cluster.simulator import _EventSimRuntime
from repro.cluster.workload import classify
from repro.core import Arrival, Decision, SchedulingPolicy, make_policy
from repro.core.constraints import evaluate_constraints
from repro.serving.kvcache import BlockAllocator, blocks_needed


# ---------------------------------------------------------------------------
# BlockAllocator
# ---------------------------------------------------------------------------


def test_allocator_basics():
    a = BlockAllocator(8)
    t1 = a.allocate(3)
    t2 = a.allocate(5)
    assert a.free_blocks == 0 and a.used_blocks == 8
    assert a.allocate(1) is None             # exhausted -> back-pressure
    assert a.allocate(0) == []               # zero-block request is fine
    a.free(t1)
    assert a.free_blocks == 3
    with pytest.raises(ValueError, match="double free"):
        a.free(t1)
    a.free(t2)
    assert a.free_blocks == 8
    assert sorted(t1 + t2) == list(range(8))  # ids are real pool slots


@given(st.lists(st.tuples(st.integers(0, 1), st.integers(0, 6)),
                max_size=40))
@settings(max_examples=50, deadline=None)
def test_allocator_conserves_pool(ops):
    """Any alloc/free interleaving conserves blocks and never hands out a
    block twice."""
    a = BlockAllocator(16)
    live = []
    for is_alloc, n in ops:
        if is_alloc or not live:
            got = a.allocate(n)
            if got is not None:
                live.append(got)
        else:
            a.free(live.pop(0))
        held = [b for t in live for b in t]
        assert len(held) == len(set(held))               # no aliasing
        assert a.free_blocks + len(held) == a.n_blocks   # conservation


def test_blocks_needed_rounds_up():
    assert blocks_needed(1, 16) == 1
    assert blocks_needed(16, 16) == 1
    assert blocks_needed(17, 16) == 2
    assert blocks_needed(0, 16) == 1       # even empty requests own a page


# ---------------------------------------------------------------------------
# Paged engine (jax-backed; mirrors tests/test_serving.py scale)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def engine_setup():
    jax = pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.models import init_params

    cfg = get_config("gemma-2b").reduced(n_layers=2, d_model=128,
                                         vocab_size=512)
    return cfg, init_params(jax.random.key(0), cfg)


def _engine(engine_setup, **kw):
    from repro.serving import ServingEngine
    cfg, params = engine_setup
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_seq", 128)
    return ServingEngine(cfg, params, **kw)


def test_paged_engine_matches_dense(engine_setup):
    """With a full-size pool, paging changes bookkeeping only: greedy
    outputs are bit-identical to the dense engine and every block returns
    to the pool."""
    dense = _engine(engine_setup)
    paged = _engine(engine_setup, paged=True, kv_block_tokens=16)
    for eng in (dense, paged):
        for i in range(7):
            eng.submit(list(range(5, 12 + i)), max_new_tokens=6)
        eng.run_until_idle()
    assert [r.generated for r in paged.completed] \
        == [r.generated for r in dense.completed]
    assert paged.kv.free_blocks == paged.kv.n_blocks
    assert paged.n_prefills == 7


def test_paged_engine_kv_pressure_serializes(engine_setup):
    """A pool holding one request at a time forces admissions to wait for
    free-on-finish — lanes alone no longer set the batch."""
    eng = _engine(engine_setup, max_batch=4, paged=True, kv_blocks=4,
                  kv_block_tokens=16)
    for _ in range(5):
        eng.submit(list(range(4, 20)), max_new_tokens=8)   # 16+8 -> 2 blks
    seen_parallel = 0
    for _ in range(10_000):
        if not eng.queue and not eng.active_slots:
            break
        seen_parallel = max(seen_parallel, eng.step())
    assert len(eng.completed) == 5
    assert seen_parallel <= 2            # 4 lanes idle; blocks bind first
    assert eng.kv.free_blocks == 4


def test_resumable_request_bypasses_stalled_head(engine_setup):
    """An evicted-resumable request (holding its pages) must pass a queue
    head stalled on allocation — otherwise its held blocks deadlock the
    pool: the head waits on blocks only the resumable request can free."""
    eng = _engine(engine_setup, max_batch=2, paged=True, kv_blocks=3,
                  kv_block_tokens=16)
    a = eng.submit(list(range(3, 19)), max_new_tokens=8)   # 2 blocks
    for _ in range(3):
        eng.step()
    eng.evict(0)                                           # a holds pages
    b = eng.submit(list(range(4, 20)), max_new_tokens=8)   # needs 2 > 1
    eng.resubmit(a)                                        # behind b
    done = eng.run_until_idle()
    assert {r.rid for r in done} == {a.rid, b.rid}
    assert eng.n_prefills == 2                             # a never re-ran
    assert eng.kv.free_blocks == 3


def test_oversized_request_rejected_at_submit(engine_setup):
    eng = _engine(engine_setup, paged=True, kv_blocks=2, kv_block_tokens=16)
    with pytest.raises(ValueError, match="never be admitted"):
        eng.submit(list(range(40)), max_new_tokens=32)


def test_evict_zeroes_slot_state(engine_setup):
    """Satellite: eviction must not leave positions/cur_tokens of the
    freed lane behind for the next admission's diagnostics."""
    eng = _engine(engine_setup, max_batch=1)
    eng.submit(list(range(3, 12)), max_new_tokens=8)
    for _ in range(3):
        eng.step()
    assert eng.positions[0] > 0 and eng.cur_tokens[0] != 0
    req = eng.evict(0)
    assert req is not None and req.slot == -1
    assert eng.positions[0] == 0 and eng.cur_tokens[0] == 0


def test_run_until_idle_surfaces_exhaustion(engine_setup):
    """Satellite: exhausting max_steps with work queued raises instead of
    silently dropping requests."""
    eng = _engine(engine_setup, max_batch=1)
    eng.submit([1, 2, 3], max_new_tokens=6)
    with pytest.raises(RuntimeError, match="remain after"):
        eng.run_until_idle(max_steps=2)
    done = eng.run_until_idle()          # a real budget finishes the work
    assert len(done) == 1


def test_evict_resubmit_continuity(engine_setup):
    """Satellite: a preempted request resumed on the same engine finishes
    its remaining tokens; with paging the page table is reattached and
    re-prefill is skipped — the final generation matches an uninterrupted
    run token for token."""
    ref = _engine(engine_setup, max_batch=1)
    r_ref = ref.submit(list(range(3, 17)), max_new_tokens=10)
    ref.run_until_idle()

    eng = _engine(engine_setup, max_batch=1, paged=True, kv_block_tokens=16)
    req = eng.submit(list(range(3, 17)), max_new_tokens=10)
    for _ in range(4):
        eng.step()
    assert 0 < len(req.generated) < 10
    victim = eng.evict(0)
    assert victim is req and req.kv is not None and req.pages is not None
    eng.resubmit(req)
    eng.run_until_idle()
    assert req.done
    assert req.generated == r_ref.generated
    assert eng.n_prefills == 1           # the resume never re-prefilled
    assert eng.kv.free_blocks == eng.kv.n_blocks


def test_dense_engine_has_no_resubmit(engine_setup):
    eng = _engine(engine_setup, max_batch=1)
    req = eng.submit([1, 2, 3], max_new_tokens=4)
    eng.step()
    eng.evict(0)
    with pytest.raises(AssertionError):
        eng.resubmit(req)


def test_live_server_same_server_requeue_skips_prefill(engine_setup):
    """PerLLMServer + paged engine: the preempted victim's requeue lands
    back on its server and resumes from its pages (2 prefills for 2
    requests, not 3)."""
    from repro.serving import ServingEngine
    from repro.serving.perllm_server import PerLLMServer

    class PreemptLatest(SchedulingPolicy):
        name = "preempt-latest"

        def __init__(self):
            self.armed = False

        def assign(self, req, view):
            assert view.kv_total_blocks is not None
            victim = None
            if self.armed and view.running and view.running[0]:
                victim = view.running[0][0].sid
            return Decision(server=0, preempt_victim=victim)

    cfg, params = engine_setup
    spec = dataclasses.replace(paper_testbed(n_edge=1)[0],
                               max_concurrency=1, kv_block_tokens=16)
    eng = ServingEngine(cfg, params, max_batch=1, max_seq=128, paged=True,
                        kv_block_tokens=16)
    policy = PreemptLatest()
    srv = PerLLMServer([spec], [eng], scheduler=policy)
    first = srv.submit([1, 2, 3], max_new_tokens=12, payload_bytes=1e4)
    for _ in range(60):
        if srv.engines[0].active_slots:
            break
        srv.step()
    assert srv.engines[0].active_slots
    progressed = len(first.engine_req.generated)
    policy.armed = True
    second = srv.submit([4, 5], max_new_tokens=2, payload_bytes=1e4)
    done = srv.run_until_idle()
    assert srv.n_preempted == 1 and first.service.preemptions == 1
    assert {sr.service.sid for sr in done} \
        == {first.service.sid, second.service.sid}
    assert eng.n_prefills == 2
    # the resumed request kept its pre-eviction progress and finished
    assert len(first.engine_req.generated) == 12 >= progressed > 0
    assert eng.kv.free_blocks == eng.kv.n_blocks


# ---------------------------------------------------------------------------
# Simulator: KV ledger, requeue charging, admission off memory
# ---------------------------------------------------------------------------


def _kv_specs(n=2, kv_blocks=64, block_tokens=64, lanes=1):
    base = paper_testbed(n_edge=max(n, 1))[:n]
    return [dataclasses.replace(s, name=f"e{i}", max_concurrency=lanes,
                                kv_blocks=kv_blocks,
                                kv_block_tokens=block_tokens)
            for i, s in enumerate(base)]


class _ScriptedPreempt(SchedulingPolicy):
    """Victim + preemptor pinned to server 0; the victim's requeue routes
    to `requeue_to`."""

    name = "scripted-preempt"

    def __init__(self, preemptor_sid, requeue_to):
        self.preemptor_sid = preemptor_sid
        self.requeue_to = requeue_to

    def assign(self, req, view):
        if req.sid == self.preemptor_sid:
            tasks = view.running[0]
            return Decision(server=0,
                            preempt_victim=tasks[0].sid if tasks else None)
        if req.preemptions:
            return Decision(server=self.requeue_to)
        return Decision(server=0)


class _RecordingRuntime(_EventSimRuntime):
    def __init__(self, sim, policy):
        super().__init__(sim, policy)
        self.bookings = []

    def dispatch(self, t, req, decision, **kw):
        super().dispatch(t, req, decision, **kw)
        if req.sid in self._inflight:
            self.bookings.append(self._inflight[req.sid])


def _run_requeue(requeue_to, t_preemptor):
    sim = Simulator(_kv_specs(), slot=None, seed=0)
    a, b = [copy.copy(s) for s in generate_workload(2, seed=0)]
    a.arrival, b.arrival = 0.0, float(t_preemptor)
    a.prompt_tokens, a.output_tokens = 1024, 96
    b.prompt_tokens, b.output_tokens = 64, 8
    a.payload_bytes = b.payload_bytes = 1e6
    for r in (a, b):
        r.class_id = classify(r)
        r.preemptions = 0
        r.kv_server, r.kv_blocks = -1, 0
    rt = _RecordingRuntime(sim, _ScriptedPreempt(b.sid, requeue_to))
    rt.loop.push(Arrival(a.arrival, requests=(a,)))
    rt.loop.push(Arrival(b.arrival, requests=(b,)))
    rt.drain()
    return rt, a, b


@given(st.floats(0.2, 8.0))
@settings(max_examples=20, deadline=None)
def test_same_server_requeue_charges_zero_reprefill(t_preemptor):
    """Acceptance property: after a KV-preserving preemption, requeueing
    on the same server books a decode-only window and banks the prompt's
    prefill tokens as savings; requeueing elsewhere pays full prefill.
    Either way the block ledger drains to zero."""
    same, a_s, _ = _run_requeue(0, t_preemptor)
    cross, a_c, _ = _run_requeue(1, t_preemptor)
    for rt, a in ((same, a_s), (cross, a_c)):
        if rt.n_preempted == 0:
            # preemptor landed before the victim's lane started (or after
            # it finished) — the runtime legitimately refused
            continue
        requeues = [bk for bk in rt.bookings
                    if bk.request.sid == a.sid and not bk.cancelled]
        assert len(requeues) == 1
        (bk,) = requeues
        j = bk.j
        spec = rt.specs[j]
        nominal_decode = spec.decode_time(a.output_tokens)
        nominal_full = spec.service_time(1024, a.output_tokens)
        # noise is lognormal(0, 0.08) and efficiency >= 0.7: the prefill
        # term (~4.6 s for 1024 tokens) dwarfs both
        if rt is same:
            assert bk.kv_resumed
            assert bk.t_inf < nominal_full / 0.7 - spec.prefill_time(1024) / 2
            assert rt.kv_prefill_tokens_saved == 1024
        else:
            assert not bk.kv_resumed
            assert bk.t_inf >= nominal_decode
            assert rt.kv_prefill_tokens_saved == 0
        assert rt.n_kv_evictions == rt.n_preempted
    assert same.kv_used == [0, 0]
    assert cross.kv_used == [0, 0]


def test_cross_server_requeue_to_unmodeled_server_frees_pages():
    """Preserved pages must be released even when the requeue routes to a
    server that models no KV — otherwise the old pool leaks forever."""
    base = paper_testbed(n_edge=2)[:2]
    specs = [dataclasses.replace(base[0], name="e0", max_concurrency=1,
                                 kv_blocks=64, kv_block_tokens=64),
             dataclasses.replace(base[1], name="e1", max_concurrency=1)]
    assert specs[1].kv_blocks == 0
    sim = Simulator(specs, slot=None, seed=0)
    a, b = [copy.copy(s) for s in generate_workload(2, seed=0)]
    a.arrival, b.arrival = 0.0, 2.0
    a.prompt_tokens, a.output_tokens = 1024, 96
    b.prompt_tokens, b.output_tokens = 64, 8
    a.payload_bytes = b.payload_bytes = 1e6
    for r in (a, b):
        r.class_id = classify(r)
        r.preemptions = 0
        r.kv_server, r.kv_blocks = -1, 0
    rt = _RecordingRuntime(sim, _ScriptedPreempt(b.sid, requeue_to=1))
    rt.loop.push(Arrival(0.0, requests=(a,)))
    rt.loop.push(Arrival(2.0, requests=(b,)))
    rt.drain()
    assert rt.n_preempted == 1
    assert len(rt.outcomes) == 2
    assert rt.kv_used == [0, 0]
    assert a.kv_server == -1 and a.kv_blocks == 0


def test_drop_kv_preemption_frees_blocks_and_reprefills():
    """Decision.preempt_drop_kv releases the victim's pages at eviction
    time: the requeue (even same-server) pays full prefill again."""

    class DropPreempt(_ScriptedPreempt):
        def assign(self, req, view):
            d = super().assign(req, view)
            if d.preempt_victim is not None:
                d = dataclasses.replace(d, preempt_drop_kv=True)
            return d

    sim = Simulator(_kv_specs(), slot=None, seed=0)
    a, b = [copy.copy(s) for s in generate_workload(2, seed=0)]
    a.arrival, b.arrival = 0.0, 2.0
    a.prompt_tokens, a.output_tokens = 1024, 96
    b.prompt_tokens, b.output_tokens = 64, 8
    a.payload_bytes = b.payload_bytes = 1e6
    for r in (a, b):
        r.class_id = classify(r)
        r.preemptions = 0
        r.kv_server, r.kv_blocks = -1, 0
    rt = _RecordingRuntime(sim, DropPreempt(b.sid, 0))
    rt.loop.push(Arrival(0.0, requests=(a,)))
    rt.loop.push(Arrival(2.0, requests=(b,)))
    rt.drain()
    assert rt.n_preempted == 1 and rt.n_kv_evictions == 1
    requeue = [bk for bk in rt.bookings
               if bk.request.sid == a.sid][-1]
    assert not requeue.kv_resumed
    assert rt.kv_prefill_tokens_saved == 0
    assert rt.kv_used == [0, 0]


def test_rejected_requeue_releases_preserved_pages():
    """A preserved-pages victim whose requeue is shed by admission control
    must return its blocks — otherwise the pool leaks forever."""

    class RejectRequeue(_ScriptedPreempt):
        def assign(self, req, view):
            if req.preemptions:
                return Decision(server=0, admit=False)
            return super().assign(req, view)

    sim = Simulator(_kv_specs(), slot=None, seed=0)
    a, b = [copy.copy(s) for s in generate_workload(2, seed=0)]
    a.arrival, b.arrival = 0.0, 2.0
    a.prompt_tokens, a.output_tokens = 1024, 96
    b.prompt_tokens, b.output_tokens = 64, 8
    a.payload_bytes = b.payload_bytes = 1e6
    for r in (a, b):
        r.class_id = classify(r)
        r.preemptions = 0
        r.kv_server, r.kv_blocks = -1, 0
    rt = _RecordingRuntime(sim, RejectRequeue(b.sid, 0))
    rt.loop.push(Arrival(0.0, requests=(a,)))
    rt.loop.push(Arrival(2.0, requests=(b,)))
    rt.drain()
    assert rt.n_preempted == 1 and rt.n_rejected == 1
    assert a.kv_server == -1 and a.kv_blocks == 0
    assert rt.kv_used == [0, 0]


def test_kv_wait_serializes_on_block_exhaustion():
    """A pinned server whose pool fits one request at a time: later
    arrivals wait for blocks (not lanes), all complete, ledger drains."""

    class Pin(SchedulingPolicy):
        name = "pin"

        def assign(self, req, view):
            return Decision(server=0)

    specs = _kv_specs(n=1, kv_blocks=20, block_tokens=64, lanes=8)
    sim = Simulator(specs, slot=None, seed=0)
    wl = [copy.copy(s) for s in generate_workload(6, seed=1)]
    for r in wl:
        r.prompt_tokens, r.output_tokens = 1000, 24    # 16 blocks apiece
        r.arrival = 0.1 * r.sid
        r.class_id = classify(r)
        r.preemptions = 0
        r.kv_server, r.kv_blocks = -1, 0
    rt = _RecordingRuntime(sim, Pin())
    for r in wl:
        rt.loop.push(Arrival(r.arrival, requests=(r,)))
    rt.drain()
    assert len(rt.outcomes) == 6
    assert all(r.finish > 0 for r in wl)
    assert rt.kv_used == [0]
    # serialized by memory: despite 8 idle lanes, no two inference
    # windows overlap (16 of 20 blocks per request -> one at a time)
    windows = sorted((bk.begin, bk.finish) for bk in rt.bookings)
    for (_, e1), (s2, _) in zip(windows, windows[1:], strict=False):
        assert e1 <= s2 + 1e-9, windows


def test_drop_kv_preemptor_gets_freed_blocks_first():
    """`preempt_drop_kv`'s contract: the victim's freed blocks go to the
    preemptor ahead of the kv_wait FIFO — the preemption exists to make
    *that* request fit, not to feed earlier waiters."""

    class Script(SchedulingPolicy):
        name = "script"

        def __init__(self, preemptor_sid):
            self.preemptor_sid = preemptor_sid

        def assign(self, req, view):
            if req.sid == self.preemptor_sid and view.running[0]:
                return Decision(server=0,
                                preempt_victim=view.running[0][0].sid,
                                preempt_drop_kv=True)
            return Decision(server=0)

    specs = _kv_specs(n=1, kv_blocks=20, block_tokens=64, lanes=8)
    sim = Simulator(specs, slot=None, seed=0)
    wl = [copy.copy(s) for s in generate_workload(3, seed=1)]
    # victim (16 blocks) runs; waiter (16) queues; preemptor (7) drops the
    # victim's pages and must claim them ahead of the waiter
    sizes = [(1000, 24), (1000, 24), (400, 24)]
    for r, (p, o) in zip(wl, sizes, strict=True):
        r.prompt_tokens, r.output_tokens = p, o
        r.arrival = [0.0, 0.5, 8.0][r.sid]
        r.class_id = classify(r)
        r.preemptions = 0
        r.kv_server, r.kv_blocks = -1, 0
    rt = _RecordingRuntime(sim, Script(wl[2].sid))
    for r in wl:
        rt.loop.push(Arrival(r.arrival, requests=(r,)))
    rt.drain()
    assert rt.n_preempted == 1
    assert len(rt.outcomes) == 3 and rt.kv_used == [0]
    starts = {}
    for bk in rt.bookings:
        starts.setdefault(bk.request.sid, bk.begin)
    # the preemptor was admitted at preemption time, before the waiter
    assert starts[wl[2].sid] <= starts[wl[1].sid]


def test_kv_wait_is_strictly_fifo_no_leapfrog():
    """A newcomer that would fit the free blocks still queues behind an
    earlier, larger waiter — matching the paged engine's head-of-line
    admission (no starvation of big requests under small-request load)."""

    class Pin(SchedulingPolicy):
        name = "pin"

        def assign(self, req, view):
            return Decision(server=0)

    specs = _kv_specs(n=1, kv_blocks=20, block_tokens=64, lanes=8)
    sim = Simulator(specs, slot=None, seed=0)
    wl = [copy.copy(s) for s in generate_workload(3, seed=1)]
    # A (16 blocks) runs; B (16) waits; C (8) would fit the 4+... free
    # blocks after A starts, but must not jump ahead of B
    sizes = [(1000, 24), (1000, 24), (400, 24)]
    for r, (p, o) in zip(wl, sizes, strict=True):
        r.prompt_tokens, r.output_tokens = p, o
        r.arrival = 0.2 * r.sid
        r.class_id = classify(r)
        r.preemptions = 0
        r.kv_server, r.kv_blocks = -1, 0
    rt = _RecordingRuntime(sim, Pin())
    for r in wl:
        rt.loop.push(Arrival(r.arrival, requests=(r,)))
    rt.drain()
    assert len(rt.outcomes) == 3 and rt.kv_used == [0]
    starts = {bk.request.sid: bk.begin for bk in rt.bookings}
    assert starts[wl[1].sid] <= starts[wl[2].sid]   # B before C


def test_oversized_request_is_shed_not_crashed():
    """A KV-blind policy routing a request bigger than a server's whole
    pool must produce a rejected Outcome, not a crashed run."""

    class Pin(SchedulingPolicy):
        name = "pin"

        def assign(self, req, view):
            return Decision(server=0)

    specs = _kv_specs(n=1, kv_blocks=4, block_tokens=64)   # 256-token pool
    sim = Simulator(specs, slot=None, seed=0)
    wl = [copy.copy(s) for s in generate_workload(3, seed=0)]
    wl[1].prompt_tokens = 4096                             # can never fit
    res = sim.run(wl, Pin())
    assert res.n_rejected == 1
    assert sorted(r.finish > 0 for r in wl) == [False, True, True]


def test_live_server_sheds_pool_oversized_request(engine_setup):
    """PerLLMServer: a routed request bigger than its engine's whole pool
    is shed at TxDone (rejected outcome) instead of crashing the loop."""
    from repro.serving import ServingEngine
    from repro.serving.perllm_server import PerLLMServer

    cfg, params = engine_setup
    spec = dataclasses.replace(paper_testbed(n_edge=1)[0],
                               kv_block_tokens=16)
    eng = ServingEngine(cfg, params, max_batch=1, max_seq=128, paged=True,
                        kv_blocks=2, kv_block_tokens=16)   # 32-token pool
    srv = PerLLMServer([spec], [eng])
    ok = srv.submit([1, 2, 3], max_new_tokens=4, payload_bytes=1e4)
    big = srv.submit(list(range(3, 60)), max_new_tokens=8,
                     payload_bytes=1e4)                    # 65 tokens
    done = srv.run_until_idle()
    assert [sr.service.sid for sr in done] == [ok.service.sid]
    assert len(srv.rejected) == 1
    assert srv.rejected[0].service.sid == big.service.sid
    assert eng.kv.free_blocks == 2


def test_kv_admission_sheds_on_memory_exhaustion():
    """PerLLM admission control driven by kv_free_blocks: on a KV-starved
    testbed it sheds requests that an unstarved testbed admits."""
    wl = generate_workload(400, rate=10.0, seed=0, scenario="kv-pressure")
    runs = {}
    for starved in (False, True):
        specs = paper_testbed("llama2-7b",
                              kv_blocks=64 if starved else 100_000,
                              kv_block_tokens=64)
        sim = Simulator(specs, slot=None, seed=42)
        runs[starved] = sim.run(
            [copy.copy(s) for s in wl],
            make_policy("perllm", len(specs), admission=True))
    assert runs[True].n_rejected > runs[False].n_rejected
    assert runs[True].n_rejected > 0


def test_view_and_constraints_expose_kv():
    specs = _kv_specs(n=2, kv_blocks=32, block_tokens=64)
    seen = {}

    class Peek(SchedulingPolicy):
        name = "peek"

        def assign(self, req, view):
            seen["free"] = list(view.kv_free_blocks)
            seen["total"] = list(view.kv_total_blocks)
            seen["slack"] = evaluate_constraints(req, 0, view).kv
            return Decision(server=0)

    sim = Simulator(specs, slot=None, seed=0)
    sim.run([copy.copy(s) for s in generate_workload(3, seed=0)], Peek())
    assert seen["total"] == [32, 32]
    assert all(0 <= f <= 32 for f in seen["free"])
    assert seen["slack"] <= 1.0
    # unmodeled testbeds keep the vacuous slack (and no kv view fields)
    sim2 = Simulator(paper_testbed()[:2], slot=None, seed=0)
    seen2 = {}

    class Peek2(SchedulingPolicy):
        name = "peek2"

        def assign(self, req, view):
            seen2["free"] = view.kv_free_blocks
            seen2["slack"] = evaluate_constraints(req, 0, view).kv
            return Decision(server=0)

    sim2.run([copy.copy(s) for s in generate_workload(2, seed=0)], Peek2())
    assert seen2["free"] is None and seen2["slack"] == 1.0


# ---------------------------------------------------------------------------
# kv-pressure scenario
# ---------------------------------------------------------------------------


def test_kv_pressure_scenario_shapes_requests():
    base = generate_workload(200, seed=7)
    shaped = generate_workload(200, seed=7, scenario="kv-pressure")
    assert np.mean([r.prompt_tokens for r in shaped]) \
        > 2 * np.mean([r.prompt_tokens for r in base])
    assert np.mean([r.payload_bytes for r in shaped]) \
        < 0.2 * np.mean([r.payload_bytes for r in base])
    # arrivals are a fresh (faster) process, requirements deterministic
    again = generate_workload(200, seed=7, scenario="kv-pressure")
    assert [r.prompt_tokens for r in again] \
        == [r.prompt_tokens for r in shaped]
    assert shaped[-1].arrival < base[-1].arrival
