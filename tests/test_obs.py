"""Observability: trace recorder, metrics registry, exporters.

Three contracts under test:

* **Zero-interference** — attaching a TraceRecorder changes *nothing*
  about a run: every `SimResult` field is bit-identical traced vs
  untraced, on both sim cores (golden), and the live server's outcomes
  are unchanged too.
* **Span accounting** — for every completed request the TX, QUEUE and
  INFER spans telescope exactly to its end-to-end processing time
  (conservation property; no gaps, no overlaps).
* **Export validity** — the Perfetto trace_event JSON passes the schema
  checker and the CSV round-trips the row count.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.cluster import Simulator, generate_workload, paper_testbed
from repro.core import make_policy
from repro.obs import (
    DEPRECATED_ALIASES, KIND_ARM, KIND_DONE, KIND_INFER, KIND_QUEUE,
    KIND_REJECT, KIND_TX, MetricsRegistry, TraceRecorder, with_aliases,
    write_csv, write_perfetto,
)
from repro.obs.export import validate_perfetto


def _run(core, trace=None, n=300, n_edge=6, rate=60.0, seed=11):
    specs = paper_testbed(n_edge=n_edge)
    sim = Simulator(specs, core=core)
    services = generate_workload(n, rate=rate, seed=seed)
    policy = make_policy("perllm", len(specs))
    return sim.run(services, policy, trace=trace)


def _fields_equal(a, b):
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, np.ndarray):
            assert np.array_equal(va, vb), f.name
        else:
            assert va == vb, (f.name, va, vb)


# ---------------------------------------------------------------------------
# zero-interference goldens
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("core", ["array", "reference"])
def test_traced_run_bit_identical(core):
    base = _run(core)
    traced = _run(core, trace=TraceRecorder())
    _fields_equal(base, traced)


def test_cross_core_traces_identical():
    ra, rb = TraceRecorder(), TraceRecorder()
    _run("array", trace=ra)
    _run("reference", trace=rb)
    ca, cb = ra.to_arrays(), rb.to_arrays()
    assert len(ca["kind"]) == len(cb["kind"]) > 0
    for name in ca:
        assert np.array_equal(ca[name], cb[name]), name


# ---------------------------------------------------------------------------
# span accounting (conservation)
# ---------------------------------------------------------------------------

def test_span_conservation():
    rec = TraceRecorder()
    _run("array", trace=rec)
    cols = rec.to_arrays()
    kind, sid = cols["kind"], cols["sid"]
    t0, t1 = cols["t0"], cols["t1"]
    checked = 0
    for s in np.unique(sid[kind == KIND_DONE]):
        m = sid == s
        # preempted requests re-enter and own several TX windows; the
        # telescoping identity is for the single-pass lifecycle
        if np.count_nonzero(m & (kind == KIND_TX)) != 1:
            continue
        total = 0.0
        for k in (KIND_TX, KIND_QUEUE, KIND_INFER):
            i = np.flatnonzero(m & (kind == k))
            assert i.size == 1
            total += float(t1[i[0]] - t0[i[0]])
        start = float(t0[np.flatnonzero(m & (kind == KIND_TX))[0]])
        finish = float(t1[np.flatnonzero(m & (kind == KIND_DONE))[0]])
        assert total == pytest.approx(finish - start, abs=1e-9)
        checked += 1
    assert checked > 50


def test_rejects_and_arm_pulls_recorded():
    specs = paper_testbed(n_edge=2)
    sim = Simulator(specs)
    # overload a tiny testbed so admission control actually sheds
    services = generate_workload(300, rate=500.0, seed=3)
    policy = make_policy("perllm", len(specs), admission=True)
    rec = TraceRecorder()
    res = sim.run(services, policy, trace=rec)
    cols = rec.to_arrays()
    n_reject = int((cols["kind"] == KIND_REJECT).sum())
    assert n_reject == res.n_rejected > 0
    # one CSUCB arm-pull row per bandit update
    if policy.bandit is not None and rec is not None:
        assert int((cols["kind"] == KIND_ARM).sum()) == 0  # not attached
        rec2 = TraceRecorder()
        sim2 = Simulator(specs)
        pol2 = make_policy("perllm", len(specs), admission=True)
        pol2.bandit.trace = rec2
        sim2.run(generate_workload(200, rate=500.0, seed=3), pol2,
                 trace=rec2)
        assert int((rec2.to_arrays()["kind"] == KIND_ARM).sum()) > 0


# ---------------------------------------------------------------------------
# recorder unit behaviour
# ---------------------------------------------------------------------------

def test_recorder_complete_expands_to_schema_rows():
    rec = TraceRecorder()
    rec.complete(7, 1.0, 2.0, 3.5, 5.0, server=4, class_id=2, tier=1,
                 lane=3, e_tx=0.25, e_inf=1.5, tokens=64, success=True)
    cols = rec.to_arrays()
    assert len(rec) == 4 and rec.dropped == 0
    assert cols["kind"].tolist() == [KIND_TX, KIND_QUEUE, KIND_INFER,
                                     KIND_DONE]
    assert cols["t0"].tolist() == [1.0, 2.0, 3.5, 5.0]
    assert cols["t1"].tolist() == [2.0, 3.5, 5.0, 5.0]
    assert cols["sid"].tolist() == [7] * 4
    assert cols["server"].tolist() == [4] * 4
    assert cols["tier"].tolist() == [1] * 4
    assert cols["aux"].tolist() == [-1, 3, 3, -1]
    assert cols["energy"].tolist() == [0.25, 0.0, 1.5, 0.0]
    assert cols["value"].tolist() == [0.0, 0.0, 64.0, 1.0]


def test_recorder_sorts_rows_chronologically():
    rec = TraceRecorder()
    rec.append(KIND_REJECT, 9, 4.0, 4.0)
    rec.complete(1, 0.5, 1.0, 1.5, 2.0)
    rec.append(KIND_REJECT, 2, 0.25, 0.25)
    cols = rec.to_arrays()
    assert cols["t0"].tolist() == [0.25, 0.5, 1.0, 1.5, 2.0, 4.0]


def test_recorder_ring_drops_oldest():
    rec = TraceRecorder(capacity=8)
    for i in range(12):
        rec.append(KIND_REJECT, i, float(i), float(i))
    assert len(rec) == 8
    assert rec.dropped == 4
    assert rec.to_arrays()["sid"].tolist() == list(range(4, 12))
    # the completion table rings independently at capacity // 4 records
    rec = TraceRecorder(capacity=8)
    for i in range(5):
        rec.complete(i, float(i), float(i), float(i), float(i))
    assert len(rec) == 8 and rec.dropped == 12
    assert sorted(set(rec.to_arrays()["sid"].tolist())) == [3, 4]


def test_recorder_intern_and_labels():
    rec = TraceRecorder()
    a = rec.intern("0->1")
    b = rec.intern("2->1")
    assert rec.intern("0->1") == a != b
    assert rec.label(a) == "0->1" and rec.label(b) == "2->1"
    assert rec.label(99) is None
    assert rec.labels == ["0->1", "2->1"]


def test_recorder_empty_and_timeline():
    rec = TraceRecorder()
    cols = rec.to_arrays()
    assert all(len(c) == 0 for c in cols.values())
    rec.complete(5, 0.0, 1.0, 2.0, 3.0)
    rec.complete(6, 0.0, 1.0, 2.0, 3.0)
    tl = rec.timeline(5)
    assert tl["sid"].tolist() == [5] * 4


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def test_perfetto_export_schema(tmp_path):
    rec = TraceRecorder()
    _run("array", trace=rec, n=150)
    path = str(tmp_path / "trace.json")
    n_events = write_perfetto(rec, path)
    assert n_events > 0
    assert validate_perfetto(path) == []
    doc = json.loads(open(path).read())
    events = doc["traceEvents"]
    phases = {ev["ph"] for ev in events}
    assert "X" in phases and "M" in phases
    # every complete event carries the trace_event-required keys
    for ev in events:
        assert {"ph", "pid", "ts"} <= set(ev)
        if ev["ph"] == "X":
            assert "dur" in ev and ev["dur"] >= 0


def test_perfetto_validator_flags_garbage(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": [{"name": "no-ph"}]}))
    assert validate_perfetto(str(bad)) != []
    assert validate_perfetto(str(tmp_path / "missing.json")) != []


def test_csv_export_row_count(tmp_path):
    rec = TraceRecorder()
    _run("array", trace=rec, n=100)
    path = str(tmp_path / "trace.csv")
    n = write_csv(rec, path)
    assert n == len(rec)
    lines = open(path).read().strip().splitlines()
    assert len(lines) == n + 1
    assert lines[0].startswith("kind,sid,t0,t1,server")


# ---------------------------------------------------------------------------
# metrics registry & canonical naming
# ---------------------------------------------------------------------------

def test_registry_counters_and_gauges():
    m = MetricsRegistry()
    m.inc("n_served")
    m.inc("n_served", 2)
    m.inc("n_served", 3, server=1)
    assert m.get_scalar("n_served") == 3
    assert m.get("n_served", server=1) == 3
    assert m.total("n_served") == 6
    m.set_gauge("kv_free_blocks", 17, server=0)
    assert m.gauge("kv_free_blocks", server=0) == 17
    assert m.gauge("kv_free_blocks", server=9, default=-1) == -1


def test_registry_histogram_observe_paths_agree():
    m = MetricsRegistry()
    m.register_histogram("lat", [0.5, 1.0, 2.0])
    vals = [0.1, 0.6, 0.6, 1.5, 9.0]
    for v in vals:
        m.observe("lat", v)
    m2 = MetricsRegistry()
    m2.register_histogram("lat", [0.5, 1.0, 2.0])
    m2.observe_many("lat", vals)
    assert m.histogram("lat") == m2.histogram("lat")
    edges, counts, total, n = m.histogram("lat")
    assert counts == [1, 2, 1, 1] and n == 5
    assert total == pytest.approx(sum(vals))
    with pytest.raises(KeyError):
        m.observe("unregistered", 1.0)


def test_registry_as_dict_snapshot():
    m = MetricsRegistry()
    m.inc("n_served", 4, server=2)
    m.set_gauge("queue_depth", 3)
    m.register_histogram("lat", [1.0])
    m.observe("lat", 0.5)
    snap = m.as_dict()
    assert snap["counters"]["n_served"]["server=2"] == 4
    assert snap["gauges"]["queue_depth"][""] == 3
    assert snap["histograms"]["lat"][""]["counts"] == [1, 0]


def test_deprecated_aliases_cover_old_names():
    stats = with_aliases({"n_served": 5, "n_rejected": 1,
                          "avg_processing_time": 0.5})
    assert stats["served"] == 5
    assert stats["rejected"] == 1
    assert stats["mean_latency"] == 0.5
    # canonical keys always win; aliases never overwrite
    assert with_aliases({"n_served": 2, "served": 9})["served"] == 9


def test_simresult_stats_canonical_and_aliased():
    res = _run("array", n=200)
    stats = res.stats()
    for old, new in DEPRECATED_ALIASES.items():
        if new in stats:
            assert stats[old] == stats[new], (old, new)
    assert stats["n_served"] + stats["n_rejected"] == res.n_services
    assert stats["served"] == stats["n_served"]
