"""Property test: the array-backed fast core is result-identical to the
scalar reference core on randomized small workloads.

The PR-8 vectorization rebuilt `_EventSimRuntime` around array ledgers,
a flat event heap, and lazily-built views; `core="reference"` keeps the
original scalar event loop (`cluster/reference_sim.py`) as the readable
spec. The seeded golden in `test_runtime.py` pins one benchmark
workload; this file sweeps randomized (n, rate, seeds, testbed size,
bandwidth mode, policy) corners so a fast-path divergence that happens
to cancel on the golden still gets caught.
"""
import copy

from hypothesis import given, settings, strategies as st

from repro.cluster import (
    BandwidthModel, Simulator, generate_workload, paper_testbed,
)
from repro.core import make_policy


def _run(core, specs, services, policy_name, fluctuating, bw_seed,
         sim_seed):
    sim = Simulator(specs,
                    BandwidthModel(fluctuating=fluctuating, seed=bw_seed),
                    seed=sim_seed, core=core)
    svcs = [copy.copy(s) for s in services]
    res = sim.run(svcs, make_policy(policy_name, len(specs)))
    return res, svcs


@given(
    n=st.integers(1, 120),
    rate=st.sampled_from([2.0, 10.0, 50.0]),
    wl_seed=st.integers(0, 1000),
    bw_seed=st.integers(0, 1000),
    sim_seed=st.integers(0, 1000),
    n_edge=st.integers(1, 6),
    fluctuating=st.sampled_from([False, True]),
    policy_name=st.sampled_from(["perllm", "fineinfer", "agod"]),
)
@settings(max_examples=12, deadline=None)
def test_array_core_matches_reference_on_random_workloads(
        n, rate, wl_seed, bw_seed, sim_seed, n_edge, fluctuating,
        policy_name):
    specs = paper_testbed(n_edge=n_edge)
    services = generate_workload(n, rate=rate, seed=wl_seed)

    ref, ref_svcs = _run("reference", specs, services, policy_name,
                         fluctuating, bw_seed, sim_seed)
    res, new_svcs = _run("array", specs, services, policy_name,
                         fluctuating, bw_seed, sim_seed)

    assert res.success_rate == ref.success_rate
    assert res.avg_processing_time == ref.avg_processing_time
    assert res.p95_processing_time == ref.p95_processing_time
    assert res.makespan == ref.makespan
    assert res.e_tx == ref.e_tx
    assert res.e_infer == ref.e_infer
    assert res.e_idle == ref.e_idle
    assert res.per_server_served == ref.per_server_served
    key = lambda r: r.sid  # noqa: E731
    assert [r.server for r in sorted(new_svcs, key=key)] \
        == [r.server for r in sorted(ref_svcs, key=key)]
